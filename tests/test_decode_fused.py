"""Decode megakernel (ISSUE 8): one Pallas program per layer applying
norm/attention/MLP AND the X-PEFT adapter at decode shapes (T=1). The
kernel body and the jnp oracle share `decode_block_row` verbatim, so
interpret-vs-ref parity is BITWISE on every adapter route; the engine
gate is exact token equality against the composed path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.kernels import ops
from repro.models import init_lm
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, params, store


def _kernel_inputs(setup, adapter):
    """Random decode-shaped inputs + layer-0 weights/adapter leaves."""
    cfg, params, _ = setup
    B, S = 4, 32
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    block = jax.tree.map(lambda t: t[0], params["blocks"])
    ks = jax.random.split(jax.random.key(7), 4)
    dt = jnp.dtype(cfg.dtype)
    x = jax.random.normal(ks[0], (B, 1, cfg.d_model), dt)
    kc = jax.random.normal(ks[1], (B, S, KV, hd), dt)
    vc = jax.random.normal(ks[2], (B, S, KV, hd), dt)
    pos = jnp.asarray([3, 0, 17, 9], jnp.int32)
    masks_l = {}
    if adapter != "none":
        table = XP.init_profile_table(ks[3], cfg)
        prof = XP.gather_profiles(table, jnp.arange(B))
        agg = jax.vmap(lambda p: XP.precompute_effective_adapters(
            params["xpeft_bank"], p, cfg.xpeft))(prof)
        lay = {k: v[:, 0] for k, v in agg.items()}     # layer-0 leaves
        if adapter == "bf16":
            masks_l = lay
        else:
            from repro.quant import schemes as QS
            qa = QS.quantize(lay["a_hat"], adapter,
                             group=cfg.xpeft.quant_group)
            qb = QS.quantize(lay["b_hat"], adapter,
                             group=cfg.xpeft.quant_group)
            masks_l = {"a_q": qa["q"], "a_scale": qa["scale"],
                       "b_q": qb["q"], "b_scale": qb["scale"],
                       "ln_scale": lay["ln_scale"],
                       "ln_bias": lay["ln_bias"]}
    kw = dict(norm=cfg.norm, qkv_bias=cfg.qkv_bias,
              use_rope=cfg.pos == "rope", theta=cfg.rope_theta,
              cap=cfg.logit_softcap, mlp_type=cfg.mlp_type,
              act_name=cfg.act, adapter=adapter,
              adapter_act=cfg.xpeft.adapter_activation)
    return (x, pos, block, kc, vc, masks_l), kw


@pytest.mark.parametrize("adapter", ["none", "bf16", "int8", "int4"])
def test_megakernel_interpret_ref_bitwise(setup, adapter):
    """The exact Pallas kernel body (interpret mode) vs the jnp oracle at
    decode shapes: y and the written K/V rows bitwise equal on every
    precision route."""
    args, kw = _kernel_inputs(setup, adapter)
    # jit both routes: the engine only ever runs them inside the jitted
    # decode step, and eager op-by-op dispatch fuses (FMA) differently
    ref = jax.jit(lambda *a: ops.decode_block_fused(
        *a, impl="ref", **kw))(*args)
    itp = jax.jit(lambda *a: ops.decode_block_fused(
        *a, impl="interpret", **kw))(*args)
    for r, i, name in zip(ref, itp, ("y", "k_rows", "v_rows")):
        assert r.dtype == i.dtype and r.shape == i.shape
        assert np.array_equal(np.asarray(r), np.asarray(i)), \
            f"{adapter}/{name} interpret != ref"


def _drain(setup, *, fused, quant="none", continuous=True, impl="auto"):
    from benchmarks.cb_smoke import skewed_requests
    cfg, params, store = setup
    cfg = cfg.with_(decode_fused=fused).with_xpeft(
        bank_quant=quant, kernel_impl=impl)
    if quant != "none":
        store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                             cfg.xpeft.bottleneck, "hard", cfg.xpeft.k,
                             quant=quant)
        table = XP.init_profile_table(jax.random.key(0), cfg)
        for pid in range(3):
            store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      sync_every=4, continuous=continuous, page_size=16)
    reqs = skewed_requests(cfg, 6, seed=0, long_new=20)
    eng.run_until_drained(reqs)
    return eng, {r.uid: list(map(int, r.generated)) for r in reqs}


@pytest.mark.parametrize("quant", ["none", "int8", "int4"])
def test_megakernel_engine_token_parity(setup, quant):
    """decode_fused=True serves bitwise the composed engine's tokens on
    the paged continuous path — bf16 and both quantized record routes —
    and the decode step still compiles exactly once."""
    _, ref = _drain(setup, fused=False, quant=quant)
    eng, toks = _drain(setup, fused=True, quant=quant)
    assert toks == ref
    assert eng.serve_stats()["step_traces"] == 1


def test_megakernel_engine_interpret_impl(setup):
    """kernel_impl only picks the backend inside the megakernel path —
    interpret mode (the exact kernel body) serves the same tokens."""
    _, ref = _drain(setup, fused=False)
    _, toks = _drain(setup, fused=True, impl="interpret")
    assert toks == ref


def test_megakernel_windowed_engine(setup):
    _, ref = _drain(setup, fused=False, continuous=False)
    _, toks = _drain(setup, fused=True, continuous=False)
    assert toks == ref


def test_megakernel_ineligible_shapes_compose(setup):
    """T>1 (prefill) and cacheless forwards must keep the composed path:
    the route resolver returns None for them."""
    from repro.models.model import _decode_fused_route
    cfg, _, _ = setup
    cfg = cfg.with_(decode_fused=True)
    masks = {"a_hat": None}
    assert _decode_fused_route(cfg, masks, True, 1) == "bf16"
    assert _decode_fused_route(cfg, masks, True, 4) is None   # prefill
    assert _decode_fused_route(cfg, masks, False, 1) is None  # no cache
    assert _decode_fused_route(cfg, None, True, 1) == "none"  # bare PLM
    off = cfg.with_(decode_fused=False)
    assert _decode_fused_route(off, masks, True, 1) is None
