"""ProfileStore: byte-level persistence and Table-1 accounting."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import masks as M
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.configs import get_config, reduce_for_smoke


def _store_with_profiles(mask_type="hard"):
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    table = XP.init_profile_table(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, mask_type, cfg.xpeft.k)
    for pid in range(4):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, table, store


def test_hard_roundtrip_preserves_topk(tmp_path):
    cfg, table, store = _store_with_profiles("hard")
    store.save(str(tmp_path / "profiles.npz"))
    loaded = ProfileStore.load(str(tmp_path / "profiles.npz"))
    for pid in range(4):
        wa, _ = store.mask_weights(pid)
        wa2, _ = loaded.mask_weights(pid)
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wa2))
        # weights match binarized top-k of the trained logits
        want = M.khot_weights_from_bits(
            np.asarray(M.binarize(table["mA"][pid], cfg.xpeft.k)),
            cfg.xpeft.k)
        np.testing.assert_allclose(np.asarray(wa), np.asarray(want))


def test_bytes_accounting_paper_factor():
    """Hard-mask storage is ~10^4x smaller than a stored adapter
    (paper Fig.1 / Table 1 claim at paper dims)."""
    store = ProfileStore(num_layers=12, num_adapters=100, bottleneck=48,
                         mask_type="hard", k=50)
    per = store.bytes_per_profile()
    adapter = M.adapter_bytes(768, 48, 12)  # fp32 Pfeiffer adapter
    assert per == 312
    factor = adapter / per
    assert factor > 5_000, factor  # 3.5MB / 312B ≈ 11,340x


def test_sparse_indices_match_dense_weights():
    cfg, table, store = _store_with_profiles("hard")
    ia, wa, ib, wb = store.sparse_indices(1)
    dense_wa, _ = store.mask_weights(1)
    k = cfg.xpeft.k
    for l in range(cfg.num_layers):
        sel = np.where(np.asarray(dense_wa[l]) > 0)[0]
        np.testing.assert_array_equal(np.sort(np.asarray(ia[l])), sel)


def test_soft_store_roundtrip(tmp_path):
    """Soft masks survive save→load: fp16 logits and LN affines byte-exact,
    hydrated softmax weights identical."""
    cfg, table, store = _store_with_profiles("soft")
    wa, wb = store.mask_weights(2)
    np.testing.assert_allclose(np.asarray(wa.sum(-1)), 1.0, rtol=1e-3)
    store.save(str(tmp_path / "soft.npz"))
    loaded = ProfileStore.load(str(tmp_path / "soft.npz"))
    assert loaded.mask_type == "soft"
    for pid in range(4):
        wa, wb = store.mask_weights(pid)
        wa2, wb2 = loaded.mask_weights(pid)
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wa2))
        np.testing.assert_array_equal(np.asarray(wb), np.asarray(wb2))
        ls, lb = store.ln_affines([pid])
        ls2, lb2 = loaded.ln_affines([pid])
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(ls2))
        np.testing.assert_array_equal(np.asarray(lb), np.asarray(lb2))


def test_save_leaves_no_temp_files(tmp_path):
    """np.savez appends .npz to suffix-less temp names; save() must not
    leave the original empty mkstemp file behind."""
    _, _, store = _store_with_profiles("hard")
    store.save(str(tmp_path / "profiles.npz"))
    store.save(str(tmp_path / "profiles.npz"))  # overwrite path too
    assert sorted(p.name for p in tmp_path.iterdir()) == ["profiles.npz"]


def test_batch_public_hydration_api():
    """batch_sparse_indices/ln_affines (the serving hydration API) match
    the per-profile calls, stacked."""
    cfg, table, store = _store_with_profiles("hard")
    pids = [2, 0, 1]
    ia, wa, ib, wb = store.batch_sparse_indices(pids)
    assert ia.shape == (3, cfg.num_layers, cfg.xpeft.k)
    ls, lb = store.ln_affines(pids)
    assert ls.shape == (3, cfg.num_layers, cfg.xpeft.bottleneck)
    for r, pid in enumerate(pids):
        pia, pwa, pib, pwb = store.sparse_indices(pid)
        np.testing.assert_array_equal(np.asarray(ia[r]), np.asarray(pia))
        np.testing.assert_array_equal(np.asarray(ib[r]), np.asarray(pib))
