"""Observability layer (ISSUE 10): histograms, span tracer, retrace
sentinel, watchdog mirroring, the serve_stats() schema across engine
variants, and the zero-denominator rate / reset_stats contracts."""
import json

import numpy as np
import jax
import pytest

from repro import obs as OBS
from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.obs import trace as TR
from repro.obs.metrics import ExpHistogram, MetricsRegistry, StepWatchdog
from repro.obs.sentinel import RetraceError, RetraceSentinel
from repro.serve.engine import Request, ServeEngine, _rate


# ---------------------------------------------------------------- histograms

def test_exp_histogram_percentiles():
    h = ExpHistogram(unit="us")
    for v in range(1, 1001):
        h.record(float(v))
    s = h.snapshot()
    assert s["count"] == 1000 and s["min"] == 1.0 and s["max"] == 1000.0
    # base 2**(1/8) bounds relative error at ~9%
    assert abs(s["p50"] - 500) / 500 < 0.10
    assert abs(s["p99"] - 990) / 990 < 0.10
    assert s["p50"] <= s["p95"] <= s["p99"] <= s["max"]


def test_exp_histogram_nonpositive_and_empty():
    h = ExpHistogram()
    assert h.snapshot() == {"count": 0, "unit": ""}
    assert h.percentile(50) == 0.0
    h.record(0.0)
    h.record(-3.0)
    h.record(5.0)
    # non-positive values pool in a sentinel bucket that reports 0.0;
    # the exact extremes survive in the snapshot min/max
    assert h.percentile(1) == 0.0
    assert h.percentile(100) == 5.0
    s = h.snapshot()
    assert s["min"] == -3.0 and s["max"] == 5.0


def test_registry_snapshot_and_disabled():
    reg = MetricsRegistry()
    reg.inc("a")
    reg.inc("a", 2)
    reg.set_gauge("g", 7)
    reg.observe("h", 10.0, "us")
    s = reg.snapshot()
    assert s["counters"]["a"] == 3 and s["gauges"]["g"] == 7.0
    assert s["histograms"]["h"]["count"] == 1
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}
    off = MetricsRegistry(enabled=False)
    off.inc("a")
    off.set_gauge("g", 1)
    off.observe("h", 1.0)
    assert off.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_registry_export(tmp_path):
    reg = MetricsRegistry()
    reg.observe("lat", 3.0, "us")
    p = tmp_path / "m.json"
    reg.export(str(p))
    assert json.loads(p.read_text())["histograms"]["lat"]["count"] == 1


# -------------------------------------------------------------------- tracer

def test_tracer_spans_export_and_validate(tmp_path):
    tr = OBS.SpanTracer()
    with tr.span(TR.CAT_ADMISSION, "admit_wave", offered=3) as sp:
        sp["admitted"] = 2  # the yielded dict IS the event's args
    tr.instant(TR.CAT_RESILIENCE, "degraded", profile=1)
    tr.complete(TR.CAT_DECODE_WINDOW, "w", 0.0, 0.5, steps=4)
    p = tmp_path / "trace.json"
    doc = tr.export(str(p))
    assert OBS.validate_chrome_trace(doc) is None
    assert OBS.validate_chrome_trace(json.loads(p.read_text())) is None
    evs = {e["name"]: e for e in tr.events()}
    assert evs["admit_wave"]["args"] == {"offered": 3, "admitted": 2}
    assert evs["admit_wave"]["ph"] == "X" and evs["degraded"]["ph"] == "i"
    assert evs["w"]["dur"] == pytest.approx(0.5e6)
    assert tr.category_counts() == {"admission": 1, "resilience": 1,
                                    "decode-window": 1}
    assert OBS.validate_chrome_trace({"traceEvents": [{"name": "x"}]})


def test_tracer_ring_bound_and_disabled():
    tr = OBS.SpanTracer(capacity=4)
    for i in range(10):
        tr.instant(TR.CAT_SPEC, f"e{i}")
    assert len(tr.events()) == 4 and tr.dropped == 6
    off = OBS.SpanTracer(enabled=False)
    with off.span(TR.CAT_PREFILL, "p", rows=2) as sp:
        sp["extra"] = 1  # must not raise on the disabled path
    off.instant(TR.CAT_SPEC, "i")
    assert off.events() == [] and off.category_counts() == {}


# ------------------------------------------------------------------ sentinel

def test_sentinel_budget_modes():
    n = {"traces": 1}
    s = RetraceSentinel(mode="raise")
    s.watch("step", lambda: n["traces"], budget=1)
    assert s.check() == []
    n["traces"] = 2
    with pytest.raises(RetraceError, match="step"):
        s.check()
    logged = []
    s2 = RetraceSentinel(mode="log", log=logged.append)
    s2.watch("step", lambda: n["traces"], budget=1)
    assert len(s2.check()) == 1 and s2.violations_seen == 1 and logged
    s3 = RetraceSentinel(mode="off")
    s3.watch("step", lambda: n["traces"], budget=1)
    assert s3.check() == [] and s3.violations_seen == 0


def test_sentinel_shape_polymorphic_contract():
    st = {"traces": 2, "shapes": 2}
    s = RetraceSentinel(mode="raise")
    s.watch("prefill", lambda: st["traces"],
            shapes_fn=lambda: st["shapes"])
    s.check()  # one trace per distinct shape: fine
    st["traces"] = 3  # same shape compiled twice = placement drift
    with pytest.raises(RetraceError, match="placement drift"):
        s.check()
    assert s.counts()["prefill"] == {"traces": 3, "budget": None,
                                     "shapes": 2}


def test_sentinel_drops_dead_watches():
    """count_fn -> None means the watched owner was collected (engines are
    held weakly); the watch must vanish instead of pinning or raising."""
    s = RetraceSentinel(mode="raise")
    owner = {"traces": 5}
    box = [owner]
    s.watch("eng", lambda: box[0]["traces"] if box[0] else None, budget=1)
    with pytest.raises(RetraceError):
        s.check()
    box[0] = None  # owner dies
    assert s.check() == [] and "eng" not in s.counts()


# ------------------------------------------------------- watchdog mirroring

def test_watchdog_mirrors_into_registry():
    reg = MetricsRegistry()
    t = {"now": 0.0}
    wd = StepWatchdog(clock=lambda: t["now"], registry=reg)
    wd.step_start()
    t["now"] = 0.010
    wd.step_end()
    wd.window_end(4, 0.040)
    h = reg.snapshot()["histograms"]["train.step_time_us"]
    assert h["count"] == 5 and h["p50"] == pytest.approx(10000, rel=0.1)


# ------------------------------------------------------------ bundle / null

def test_null_obs_is_inert():
    assert OBS.get(None) is OBS.NULL_OBS
    bundle = OBS.Observability(sentinel_mode="raise")
    assert OBS.get(bundle) is bundle
    null = OBS.NULL_OBS
    null.metrics.inc("x")
    with null.tracer.span(TR.CAT_SPEC, "s") as sp:
        sp["a"] = 1
    null.sentinel.watch("w", lambda: 99, budget=1)
    assert null.sentinel.check() == []  # off mode: never raises
    assert null.metrics.snapshot()["counters"] == {}
    assert null.tracer.events() == []


def test_rate_zero_denominator():
    assert _rate(0, 0) == 0.0
    assert _rate(5, 0) == 0.0  # pre-fix this leaked a div-by-zero guard
    assert _rate(5, 2) == 2.5
    assert _rate(1, 3, nd=2) == 0.33


# ----------------------------------------------------- serve_stats() schema

@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, params, store


# key -> type, pinned: renaming/retyping a serve_stats field breaks every
# scraper; this schema is the compatibility contract across PRs 2-10
BASE_SCHEMA = {
    "mode": str, "devices": int, "bank_quant": str,
    "useful_slot_steps": int, "stranded_slot_steps": int,
    "slot_occupancy": float, "step_traces": int,
    "resident_bytes_per_device": dict, "host_syncs": int,
    "device_steps": int, "decode_tokens": int, "committed_tokens": int,
    "committed_per_device_step": float, "syncs_per_token": float,
    "sync_every": int, "prefill_batches": int, "prefill_occupancy": float,
    "profile_cache": dict, "scheduler": dict, "degraded_requests": int,
    "degraded_slots": int, "hydration_retries": int,
    "quarantined_profiles": int, "store_integrity": dict,
}
CONTINUOUS_SCHEMA = {"preemptions": int, "resumes": int,
                     "resume_pending": int, "page_size": int,
                     "pages": dict, "mask_entries": dict}
SPEC_SCHEMA = {"gamma": int, "drafted": int, "accepted": int,
               "acceptance_rate": float, "committed_per_device_step": float,
               "per_request_acceptance": dict}


def _assert_schema(st: dict, schema: dict, label: str):
    for key, typ in schema.items():
        assert key in st, f"{label}: serve_stats missing {key!r}"
        v = st[key]
        assert isinstance(v, typ) and not (typ is int and
                                           isinstance(v, bool)), \
            f"{label}: serve_stats[{key!r}] = {v!r} is {type(v).__name__}," \
            f" schema pins {typ.__name__}"


def test_serve_stats_schema_across_engines(setup):
    """Key names/types pinned on FRESH engines of every variant — which
    also proves every rate field survives a zero denominator (the
    pre-ISSUE-10 serve_stats div-by-zero'd or fudged with max(d, 1))."""
    cfg, params, store = setup
    engines = {
        "windowed": ServeEngine(cfg, params, store, max_slots=2,
                                max_seq=64),
        "continuous": ServeEngine(cfg, params, store, max_slots=2,
                                  max_seq=64, continuous=True),
        "spec": ServeEngine(cfg.with_(spec_enable=True, spec_gamma=2),
                            params, store, max_slots=2, max_seq=64,
                            continuous=True),
    }
    hcfg = reduce_for_smoke(get_config("qwen1.5-0.5b")).with_xpeft(
        num_adapters=12, bottleneck=4, k=4, max_profiles=8,
        bank_spec=(("bottleneck", 4), ("lora", 4), ("ia3", 2),
                   ("prefix", 2)), prefix_tokens=2)
    hkey = jax.random.key(0)
    hparams = init_lm(hkey, hcfg)
    hstore = ProfileStore(hcfg.num_layers, hcfg.xpeft.num_adapters,
                          hcfg.xpeft.bottleneck, "hard", hcfg.xpeft.k,
                          bank_spec=hcfg.xpeft.bank_spec)
    htable = XP.init_profile_table(hkey, hcfg)
    hstore.add_profile(0, jax.tree.map(lambda t: t[0], htable))
    engines["hetero"] = ServeEngine(hcfg, hparams, hstore, max_slots=2,
                                    max_seq=64, continuous=True)
    for label, eng in engines.items():
        st = eng.serve_stats()
        _assert_schema(st, BASE_SCHEMA, label)
        # fresh engine: every denominator is 0 and every rate must be 0.0
        for key in ("slot_occupancy", "committed_per_device_step",
                    "syncs_per_token", "prefill_occupancy"):
            assert st[key] == 0.0, f"{label}: {key} = {st[key]} on a " \
                "fresh engine (zero-denominator rate must read 0.0)"
    _assert_schema(engines["continuous"].serve_stats(), CONTINUOUS_SCHEMA,
                   "continuous")
    _assert_schema(engines["hetero"].serve_stats(), CONTINUOUS_SCHEMA,
                   "hetero")
    st = engines["spec"].serve_stats()
    assert "spec" in st, "spec engine: serve_stats missing 'spec' block"
    _assert_schema(st["spec"], SPEC_SCHEMA, "spec")
    assert st["spec"]["acceptance_rate"] == 0.0


def test_degraded_engine_stats_obs_and_reset(setup):
    """One drained engine covers three contracts: (a) the degraded
    (bare-PLM) path keeps the serve_stats schema and counts its fallback
    requests; (b) an attached obs bundle agrees with the engine's own
    counters and traced every category the workload exercised with zero
    sentinel violations; (c) reset_stats() zeroes every PR 2-9 counter in
    one call without touching the compile-cache trace counters."""
    from repro.resilience.faults import FaultPlan

    cfg, params, store = setup
    bundle = OBS.Observability(sentinel_mode="raise")
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      sync_every=4, fault_plan=FaultPlan(fail_pids=(2,)),
                      obs=bundle)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 5 + i),
                    profile_id=i % 3, max_new_tokens=4) for i in range(5)]
    eng.run_until_drained(list(reqs))
    assert all(r.done for r in reqs)

    st = eng.serve_stats()
    _assert_schema(st, BASE_SCHEMA, "degraded")
    assert st["degraded_requests"] > 0
    assert all(r.degraded == (r.profile_id == 2) for r in reqs)

    # (b) obs agrees with the engine's own accounting
    counters = bundle.metrics.snapshot()["counters"]
    assert counters["serve.decode_tokens"] == eng.decode_tokens
    assert counters["serve.degraded_requests"] == st["degraded_requests"]
    cats = bundle.tracer.category_counts()
    for cat in (TR.CAT_ADMISSION, TR.CAT_PREFILL, TR.CAT_DECODE_WINDOW,
                TR.CAT_RESILIENCE):
        assert cats.get(cat, 0) > 0, f"no {cat} spans traced"
    hists = bundle.metrics.snapshot()["histograms"]
    assert hists["serve.ttft_us"]["count"] == len(reqs)
    assert hists["serve.ttft_us"]["p50"] > 0
    watches = bundle.sentinel.counts()
    assert watches["serve.decode_step"]["traces"] == 1
    assert bundle.sentinel.violations_seen == 0

    # (c) one reset zeroes everything PR 2-9 accumulated piecemeal
    traces_before = st["step_traces"]
    eng.reset_stats()
    st2 = eng.serve_stats()
    _assert_schema(st2, BASE_SCHEMA, "post-reset")
    for key in ("decode_tokens", "host_syncs", "device_steps",
                "prefill_batches", "useful_slot_steps",
                "stranded_slot_steps", "degraded_requests",
                "hydration_retries", "slot_occupancy", "syncs_per_token",
                "committed_per_device_step", "prefill_occupancy"):
        assert st2[key] == 0, f"reset_stats left {key} = {st2[key]}"
    assert st2["profile_cache"]["hits"] == 0
    assert st2["profile_cache"]["entries"] > 0  # reset keeps the cache warm
    assert st2["scheduler"]["submitted"] == 0
    assert st2["step_traces"] == traces_before  # compile counters survive
    assert bundle.metrics.snapshot()["counters"] == {}
