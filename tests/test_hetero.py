"""Heterogeneous adapter-type banks: one unified mask index space over
typed segments (bottleneck / LoRA / IA3 / prefix).

Pins down: construction-time bank_spec validation, the per-type kernel
dispatch table, the mixed-type sparse == sum-of-per-type-dense aggregation
property (seeded fuzz + hypothesis when available), bank_spec as store
identity (round-trip + merge guard), the zero-mask / degraded bitwise
bare-PLM contract, engine feature-interaction guards, and end-to-end
serving parity against the composed dense reference."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import adapters as A
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.kernels import ops

SPEC = (("bottleneck", 4), ("lora", 4), ("ia3", 2), ("prefix", 2))


def _hetero_cfg():
    return reduce_for_smoke(get_config("qwen1.5-0.5b")).with_xpeft(
        num_adapters=12, bottleneck=4, k=4, max_profiles=8,
        bank_spec=SPEC, prefix_tokens=2)


@pytest.fixture(scope="module")
def setup():
    cfg = _hetero_cfg()
    key = jax.random.key(0)
    from repro.models import init_lm
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k,
                         bank_spec=cfg.xpeft.bank_spec)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, params, store


# ------------------------------------------------ config-time validation

def test_bank_spec_unknown_type_raises():
    with pytest.raises(ValueError, match="bank_spec type"):
        _hetero_cfg().with_xpeft(bank_spec=(("bottleneck", 6), ("dora", 6)))


def test_bank_spec_count_mismatch_raises():
    with pytest.raises(ValueError, match="num_adapters"):
        _hetero_cfg().with_xpeft(bank_spec=(("bottleneck", 4), ("lora", 4)))


def test_bank_spec_nonpositive_count_raises():
    with pytest.raises(ValueError, match="must be"):
        _hetero_cfg().with_xpeft(
            num_adapters=4,
            bank_spec=(("bottleneck", 4), ("lora", 0)))


def test_segments_tile_the_unified_space():
    xp = _hetero_cfg().xpeft
    segs = xp.segments()
    assert [t for t, _, _ in segs] == [t for t, _ in SPEC]
    off = 0
    for (_, o, c), (_, want) in zip(segs, SPEC):
        assert o == off and c == want
        off += c
    assert off == xp.num_adapters
    assert xp.is_hetero and xp.has_prefix


def test_type_pure_spec_is_not_hetero():
    xp = _hetero_cfg().with_xpeft(
        bank_spec=(("bottleneck", 12),)).xpeft
    assert not xp.is_hetero and not xp.has_prefix
    assert xp.segments() == (("bottleneck", 0, 12),)


# ------------------------------------------------ kernel dispatch table

def test_resolve_impl_table():
    assert ops.resolve_impl("auto") in ("pallas", "ref")
    if jax.default_backend() != "tpu":
        assert ops.resolve_impl("auto") == "ref"
    for name in ("pallas", "interpret", "ref"):
        assert ops.resolve_impl(name) == name
    with pytest.raises(ValueError, match="kernel_impl"):
        ops.resolve_impl("cuda")


def test_lora_route_matches_formula_all_impls():
    key = jax.random.key(1)
    kx, ka, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (2, 6, 16), jnp.float32)
    a = jax.random.normal(ka, (2, 16, 4), jnp.float32)
    b = jax.random.normal(kb, (2, 4, 16), jnp.float32) * 0.1
    want = x + jnp.einsum("btd,bdr->btr", x, a) @ b
    for impl in ("ref", "interpret"):
        got = ops.lora_adapter(x, a, b, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    # the two impls agree bitwise (same contraction order)
    assert (np.asarray(ops.lora_adapter(x, a, b, impl="ref"))
            == np.asarray(ops.lora_adapter(x, a, b, impl="interpret"))).all()


def test_ia3_route_matches_formula_all_impls():
    key = jax.random.key(2)
    x = jax.random.normal(key, (2, 6, 16), jnp.float32)
    s = jax.random.normal(jax.random.key(3), (2, 16), jnp.float32) * 0.2
    want = x * (1.0 + s[:, None, :])
    for impl in ("ref", "interpret"):
        got = ops.ia3_apply(x, s, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
    assert (np.asarray(ops.ia3_apply(x, s, impl="ref"))
            == np.asarray(ops.ia3_apply(x, s, impl="interpret"))).all()


def test_ia3_zero_scale_is_identity_bitwise():
    x = jax.random.normal(jax.random.key(4), (2, 6, 16), jnp.float32)
    s = jnp.zeros((2, 16), jnp.float32)
    for impl in ("ref", "interpret"):
        assert (np.asarray(ops.ia3_apply(x, s, impl=impl))
                == np.asarray(x)).all()


def test_lora_zero_b_is_identity_bitwise():
    x = jax.random.normal(jax.random.key(5), (2, 6, 16), jnp.float32)
    a = jax.random.normal(jax.random.key(6), (2, 16, 4), jnp.float32)
    b = jnp.zeros((2, 4, 16), jnp.float32)
    for impl in ("ref", "interpret"):
        assert (np.asarray(ops.lora_adapter(x, a, b, impl=impl))
                == np.asarray(x)).all()


# ------------------- mixed k-sparse == sum of per-type dense (property)

def _check_sparse_equals_dense(seed: int):
    """One draw: random typed bank + random unified-space k-sparse masks;
    the segment-bucketed sparse aggregation must equal the per-type DENSE
    aggregation of the scattered weights."""
    xp = _hetero_cfg().xpeft
    L, N, k, d, kv = 2, xp.num_adapters, xp.k, 16, 8
    rng = np.random.default_rng(seed)
    bank = A.init_hetero_bank(jax.random.key(seed), L, xp, d, kv,
                              jnp.float32)
    idx_a = np.stack([rng.choice(N, size=k, replace=False)
                      for _ in range(L)])
    idx_b = np.stack([rng.choice(N, size=k, replace=False)
                      for _ in range(L)])
    w = np.full((L, k), 1.0 / k, np.float32)
    sparse = XP.precompute_effective_adapters_sparse_hetero(
        bank, jnp.asarray(idx_a), jnp.asarray(w),
        jnp.asarray(idx_b), jnp.asarray(w), xp)

    wa_d = np.zeros((L, N), np.float32)
    wb_d = np.zeros((L, N), np.float32)
    for l in range(L):
        wa_d[l, idx_a[l]] = 1.0 / k
        wb_d[l, idx_b[l]] = 1.0 / k
    dense_keys = {"bottleneck": ("a_hat", "b_hat"),
                  "lora": ("lora_a", "lora_b"), "ia3": ("ia3_s",),
                  "prefix": ("prefix_k", "prefix_v")}
    for l in range(L):
        bank_l = jax.tree.map(lambda t: t[l], bank)
        agg = XP.hetero_aggregate_dense_layer(
            bank_l, jnp.asarray(wa_d[l]), jnp.asarray(wb_d[l]), xp)
        for t, keys in dense_keys.items():
            vals = agg[t] if isinstance(agg[t], tuple) else (agg[t],)
            for key, val in zip(keys, vals):
                got = np.asarray(sparse[key][l], np.float32)
                want = np.asarray(val, np.float32).reshape(got.shape)
                np.testing.assert_allclose(
                    got, want, rtol=1e-5, atol=1e-6,
                    err_msg=f"seed={seed} layer={l} {key}")


@pytest.mark.parametrize("seed", range(8))
def test_mixed_sparse_equals_sum_of_per_type_dense(seed):
    _check_sparse_equals_dense(seed)


def test_mixed_sparse_equals_dense_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def inner(seed):
        _check_sparse_equals_dense(seed)

    inner()


# --------------------------------------------- store identity round-trip

def test_store_bank_spec_round_trip(tmp_path, setup):
    cfg, _, store = setup
    p = str(tmp_path / "store.npz")
    store.save(p)
    loaded = ProfileStore.load(p)
    assert loaded.bank_spec == cfg.xpeft.bank_spec
    for pid in store.profile_ids():
        for x, y in zip(store.sparse_indices(pid),
                        loaded.sparse_indices(pid)):
            assert (np.asarray(x) == np.asarray(y)).all()


def test_store_merge_rejects_bank_spec_mismatch(setup):
    cfg, _, store = setup
    other = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k,
                         bank_spec=(("bottleneck", 12),))
    with pytest.raises(AssertionError):
        other.merge_from(store)


# ------------------------------------------- bitwise bare-PLM contracts

def test_zero_mask_hetero_forward_is_bitwise_bare(setup):
    cfg, params, _ = setup
    from repro.models import forward
    toks = jnp.arange(2 * 10).reshape(2, 10) % cfg.vocab_size
    L, N, b = cfg.num_layers, cfg.xpeft.num_adapters, cfg.xpeft.bottleneck
    masks = {"w_a": jnp.zeros((2, L, N)), "w_b": jnp.zeros((2, L, N)),
             "ln_scale": jnp.ones((2, L, b)),
             "ln_bias": jnp.zeros((2, L, b))}
    h0, _, _ = forward(params, toks, cfg, profile_masks=None)
    h1, _, _ = forward(params, toks, cfg, profile_masks=masks)
    assert (np.asarray(h0) == np.asarray(h1)).all()


def test_degraded_hetero_request_decodes_bitwise_bare(setup):
    cfg, params, store = setup
    from repro.models import forward, lm_logits
    from repro.serve.engine import Request, ServeEngine
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64)
    prompt = np.asarray([3, 1, 4, 1, 5]) % cfg.vocab_size
    req = Request(uid=0, prompt=prompt, profile_id=777,  # missing record
                  max_new_tokens=4)
    eng.run_until_drained([req])
    assert req.degraded and getattr(req, "prefix_len", 0) == 0
    seq = list(prompt)
    for got in req.generated:
        h, _, _ = forward(params, jnp.asarray([seq]), cfg,
                          profile_masks=None)
        want = int(jnp.argmax(lm_logits(params, h[:, -1:], cfg)[0, -1]))
        assert got == want
        seq.append(got)


# --------------------------------------------- engine interaction guards

def test_engine_rejects_hetero_bank_quant(setup):
    cfg, params, store = setup
    from repro.serve.engine import ServeEngine
    qcfg = cfg.with_xpeft(bank_quant="int8")
    with pytest.raises(ValueError, match="quant"):
        ServeEngine(qcfg, params, store, max_slots=2, max_seq=64)


def test_engine_rejects_prefix_with_spec(setup):
    cfg, params, store = setup
    from repro.serve.engine import ServeEngine
    with pytest.raises(ValueError, match="spec"):
        ServeEngine(cfg.with_(spec_enable=True, spec_gamma=2), params,
                    store, max_slots=2, max_seq=64, continuous=True)


def test_engine_rejects_prefix_without_precompute(setup):
    cfg, params, store = setup
    from repro.serve.engine import ServeEngine
    with pytest.raises(ValueError, match="precompute"):
        ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                    precompute=False)


def test_engine_rejects_prefix_overflowing_max_seq(setup):
    cfg, params, store = setup
    from repro.serve.engine import ServeEngine
    big = cfg.with_xpeft(prefix_tokens=64)
    with pytest.raises(ValueError, match="prefix"):
        ServeEngine(big, params, store, max_slots=2, max_seq=64)


# ------------------------------------------------- end-to-end parity

def test_hetero_engine_matches_composed_dense_reference(setup):
    """Engine greedy decode (typed entries, prefix rows hydrated into the
    KV cache, one compiled program) == from-scratch dense forward."""
    cfg, params, store = setup
    from repro.models import forward, lm_logits
    from repro.serve.engine import Request, ServeEngine
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      continuous=True)
    reqs = [Request(uid=i, prompt=np.asarray([3, 1, 4, 1, 5]) + i,
                    profile_id=i, max_new_tokens=4) for i in range(2)]
    eng.run_until_drained(list(reqs))
    assert eng.serve_stats()["step_traces"] == 1
    for r in reqs:
        wa, wb = store.mask_weights(int(r.profile_id))
        ln_s, ln_b = store.ln_affines([int(r.profile_id)])
        masks = {"w_a": wa[None], "w_b": wb[None],
                 "ln_scale": ln_s, "ln_bias": ln_b}
        seq = list(map(int, r.prompt))
        for got in r.generated:
            h, _, _ = forward(params, jnp.asarray([seq]), cfg,
                              profile_masks=masks)
            want = int(jnp.argmax(lm_logits(params, h[:, -1:], cfg)[0, -1]))
            assert got == want
            seq.append(got)
