"""analysis/bytes.py: the shared byte math matches TRUE array bytes and
carries the quant reductions the CI gates enforce."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import bytes as AB
from repro.configs import get_config, reduce_for_smoke
from repro.quant import schemes as QS


@pytest.mark.parametrize("scheme", ["none", "int8", "int4"])
@pytest.mark.parametrize("L,N,d,b", [(2, 8, 64, 4), (3, 16, 128, 48)])
def test_bank_slice_bytes_matches_true_quantized_arrays(scheme, L, N, d, b):
    bank = {"bank_a": 0.1 * jax.random.normal(jax.random.key(0),
                                              (L, N, d, b)),
            "bank_b": 0.1 * jax.random.normal(jax.random.key(1),
                                              (L, N, b, d))}
    if scheme == "none":
        true = AB.tree_nbytes(jax.tree.map(
            lambda x: x.astype(jnp.float16), bank))  # itemsize-2 reference
        analytic = L * N * AB.bank_slice_bytes(d, b, itemsize=2)
    else:
        true = AB.tree_nbytes(QS.quantize_bank(bank, scheme, group=32))
        analytic = L * N * AB.bank_slice_bytes(d, b, scheme=scheme,
                                               group=32)
    assert analytic == true, (analytic, true)


def test_record_bytes_matches_store_record():
    """record_bytes == the true bytes of a quantized Â/B̂ record the
    ProfileStore persists (minus masks/affines, which it doesn't model)."""
    L, d, b = 2, 64, 4
    a_hat = 0.1 * jax.random.normal(jax.random.key(0), (L, d, b))
    b_hat = 0.1 * jax.random.normal(jax.random.key(1), (L, b, d))
    for scheme in ("int8", "int4"):
        qa = QS.quantize(a_hat, scheme)
        qb = QS.quantize(b_hat, scheme)
        true = AB.tree_nbytes(qa) + AB.tree_nbytes(qb)
        assert AB.record_bytes(L, d, b, scheme=scheme) == true


def test_full_config_quant_reductions_meet_gates():
    """At the FULL config's dims (N=256, k=50, bf16), the quantized
    k-sparse admission clears the acceptance floors: int8 <= 0.30x and
    int4 <= 0.20x the bf16 analytic DENSE bank bytes per request (the
    pre-k-sparse path), and both strictly beat the bf16 sparse read."""
    agg = AB.aggregation_bytes(get_config("qwen1.5-0.5b"))
    assert agg["reduction"] >= 4.0                      # PR-1 gate intact
    assert agg["int8_vs_dense"] <= 0.30
    assert agg["int4_vs_dense"] <= 0.20
    assert agg["int8_vs_sparse"] <= 0.55                # 2x is the physical
    assert agg["int4_vs_sparse"] <= 0.32                # bf16->int8 limit
    assert agg["bytes_sparse_int4"] < agg["bytes_sparse_int8"] \
        < agg["bytes_sparse"]


def test_aggregation_bytes_smoke_config_matches_engine_units():
    """Smoke config (fp32): the analytic sparse bytes equal what the
    engine's admit stats compute for one profile's aggregation."""
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    xp = cfg.xpeft
    agg = AB.aggregation_bytes(cfg)
    per_profile = AB.admission_bank_bytes(
        cfg.num_layers, xp.num_adapters, xp.k, cfg.d_model, xp.bottleneck,
        itemsize=4)
    assert agg["bytes_sparse"] == per_profile
    assert agg["bytes_dense"] // agg["bytes_sparse"] == xp.num_adapters // xp.k


def test_itemsize_for():
    assert AB.itemsize_for("bfloat16") == 2
    assert AB.itemsize_for("float32") == 4
