"""Dry-run integration: one real cell lowered+compiled on the production
mesh in a subprocess (512 host devices), validating the full launch path.
"""
import json
import os
import subprocess
import sys

import pytest


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_cell_single_pod(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen1.5-0.5b", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.load(open(
        tmp_path / "qwen1.5-0.5b_decode_32k_single_baseline.json"))
    assert rec["ok"]
    assert rec["num_devices"] == 256
    assert rec["flops_per_dev"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    # decode state (params+cache) must fit a v5e chip
    assert rec["memory"]["state_bytes_per_dev_analytic"] < 16e9


@pytest.mark.slow
def test_dryrun_cell_multi_pod(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "rwkv6-7b", "--shape", "long_500k",
         "--mesh", "multi", "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.load(open(
        tmp_path / "rwkv6-7b_long_500k_multi_baseline.json"))
    assert rec["ok"] and rec["num_devices"] == 512
